"""Out-of-core ingestion: parsers, external canonicalization, .tricsr cache,
dataset registry, and the engine plumbing that consumes cached CSRs."""
import gzip
import os

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis; use the local stub
    from _hypothesis_stub import given, settings, st

from repro.core import TriangleCounter, count_triangles_numpy, preprocess
from repro.core.preprocess import oriented_from_undirected_csr, preprocess_host_offload
from repro.graphs import (
    canonicalize_edges,
    edge_array_to_csr,
    kronecker_rmat,
)
from repro.graphs.io import (
    CSRGraph,
    CacheError,
    DATASETS,
    ExternalSortStats,
    canonicalize_edges_external,
    ingest,
    iter_edge_chunks,
    load_tricsr,
    materialize_dataset,
    parse_edge_file,
    save_tricsr,
    sniff_format,
)
from repro.graphs.io.ingest import csr_from_edge_array

DATA = os.path.join(os.path.dirname(os.path.abspath(__file__)), "data")
KARATE = os.path.join(DATA, "karate.txt")


# ---------------------------------------------------------------------------
# parsers
# ---------------------------------------------------------------------------


def test_text_parser_comments_separators_blanks(tmp_path):
    p = tmp_path / "g.txt"
    p.write_text("# c1\n% c2\n\n0 1\n1\t2\n2,0\n  3   4  \n")
    np.testing.assert_array_equal(
        parse_edge_file(p), [[0, 1], [1, 2], [2, 0], [3, 4]]
    )


def test_text_parser_chunk_bound(tmp_path):
    p = tmp_path / "g.txt"
    rng = np.random.default_rng(0)
    raw = rng.integers(0, 100, size=(997, 2))
    np.savetxt(p, raw, fmt="%d")
    chunks = list(iter_edge_chunks(p, max_chunk_edges=100))
    assert [c.shape[0] for c in chunks] == [100] * 9 + [97]
    np.testing.assert_array_equal(np.concatenate(chunks), raw)


def test_gzip_parser(tmp_path):
    p = tmp_path / "g.txt.gz"
    with gzip.open(p, "wt") as fh:
        fh.write("# zipped\n5 6\n6 7\n")
    np.testing.assert_array_equal(parse_edge_file(p), [[5, 6], [6, 7]])


def test_mtx_parser_valued_and_pattern(tmp_path):
    pv = tmp_path / "v.mtx"
    pv.write_text(
        "%%MatrixMarket matrix coordinate real symmetric\n"
        "% comment\n3 3 3\n1 2 1.5\n2 3 2.5\n3 1 0.5\n"
    )
    np.testing.assert_array_equal(parse_edge_file(pv), [[0, 1], [1, 2], [2, 0]])
    pp = tmp_path / "p.mtx"
    pp.write_text(
        "%%MatrixMarket matrix coordinate pattern general\n4 4 2\n1 4\n4 2\n"
    )
    np.testing.assert_array_equal(parse_edge_file(pp), [[0, 3], [3, 1]])


def test_mtx_rejects_non_coordinate(tmp_path):
    p = tmp_path / "d.mtx"
    p.write_text("%%MatrixMarket matrix array real general\n2 2\n1.0\n")
    with pytest.raises(ValueError, match="coordinate"):
        parse_edge_file(p)


def test_parser_rejects_malformed_line(tmp_path):
    p = tmp_path / "g.txt"
    p.write_text("0 1\nnot an edge\n")
    with pytest.raises(ValueError, match="line 2"):
        parse_edge_file(p)


def test_parser_rejects_weighted_three_column(tmp_path):
    # a consistently 3-column (weighted) file must error loudly, not
    # silently re-pair tokens across rows
    p = tmp_path / "w.txt"
    p.write_text("0 1 7\n1 2 9\n")
    with pytest.raises(ValueError, match="two integer node ids"):
        parse_edge_file(p)


def test_parser_rejects_ragged_compensating_rows(tmp_path):
    # 1-token + 3-token rows have the right *total* token count but must
    # still error (no re-pairing across rows)
    p = tmp_path / "r.txt"
    p.write_text("1\n2 3 4\n")
    with pytest.raises(ValueError, match="two integer node ids"):
        parse_edge_file(p)


def test_parser_rejects_oversized_int_with_line_number(tmp_path):
    p = tmp_path / "big.txt"
    p.write_text("0 1\n1 99999999999999999999999999\n")
    with pytest.raises(ValueError, match="line 2"):
        parse_edge_file(p)


def test_parser_rejects_negative_ids(tmp_path):
    p = tmp_path / "g.txt"
    p.write_text("0 1\n-3 4\n")
    with pytest.raises(ValueError, match="negative node id"):
        parse_edge_file(p)


def test_parser_tolerates_non_ascii_comments(tmp_path):
    p = tmp_path / "g.txt"
    p.write_bytes("# Universität header\n0 1\n1 2\n".encode("utf-8"))
    np.testing.assert_array_equal(parse_edge_file(p), [[0, 1], [1, 2]])


def test_ingest_missing_file_errors_cleanly(tmp_path):
    with pytest.raises(FileNotFoundError, match="edge list not found"):
        ingest(tmp_path / "nope.txt", cache_dir=tmp_path)


def test_sniff_format():
    assert sniff_format("a/b.txt") == "text"
    assert sniff_format("a/b.edges.gz") == "text"
    assert sniff_format("a/b.mtx") == "mtx"
    assert sniff_format("a/b.mtx.gz") == "mtx"
    with pytest.raises(ValueError):
        sniff_format("a/b.parquet")


# ---------------------------------------------------------------------------
# canonicalize: negative-id satellite + external == in-memory
# ---------------------------------------------------------------------------


def test_canonicalize_rejects_negative_ids():
    with pytest.raises(ValueError, match="negative node id"):
        canonicalize_edges(np.array([[0, 1], [-2, 3]]))


def test_canonicalize_rejects_huge_ids():
    with pytest.raises(ValueError, match="2\\*\\*31"):
        canonicalize_edges(np.array([[0, 2**31]]))


def test_external_canonicalize_rejects_negative_ids():
    with pytest.raises(ValueError, match="negative node id"):
        canonicalize_edges_external(
            iter([np.array([[1, 2], [-1, 5]])]), max_chunk_edges=10
        )


@pytest.mark.parametrize("budget", [10, 100, 100000])
def test_external_matches_in_memory(budget):
    rng = np.random.default_rng(7)
    raw = rng.integers(0, 200, size=(3000, 2))
    mem = canonicalize_edges(raw)
    stats = ExternalSortStats()
    ext = canonicalize_edges_external(
        iter(np.array_split(raw, 7)), max_chunk_edges=budget, stats_out=stats
    )
    np.testing.assert_array_equal(mem, ext)
    assert ext.dtype == mem.dtype
    if budget == 10:
        assert stats.spill_runs >= 4 and stats.merge_passes == 1
    if budget == 100000:
        assert stats.spill_runs == 0


def test_external_empty_and_single_edge():
    empty = canonicalize_edges_external(iter([]), max_chunk_edges=8)
    assert empty.shape == (0, 2)
    one = canonicalize_edges_external(
        iter([np.array([[3, 1]]), np.array([[1, 3]])]), max_chunk_edges=1
    )
    np.testing.assert_array_equal(one, [[1, 3], [3, 1]])


# ---------------------------------------------------------------------------
# .tricsr cache
# ---------------------------------------------------------------------------


def test_tricsr_roundtrip_mmap_and_heap(tmp_path):
    e = kronecker_rmat(7, seed=4)
    csr = csr_from_edge_array(e)
    path = tmp_path / "g.tricsr"
    save_tricsr(path, csr)
    for mmap in (True, False):
        back = load_tricsr(path, mmap=mmap, verify=True)
        assert back.n_nodes == csr.n_nodes
        np.testing.assert_array_equal(back.row_offsets, csr.row_offsets)
        np.testing.assert_array_equal(back.col, csr.col)


def test_tricsr_detects_corruption(tmp_path):
    csr = csr_from_edge_array(kronecker_rmat(6, seed=1))
    path = tmp_path / "g.tricsr"
    save_tricsr(path, csr)
    blob = bytearray(path.read_bytes())
    blob[-3] ^= 0xFF  # flip a byte inside the col payload
    path.write_bytes(blob)
    with pytest.raises(CacheError, match="checksum"):
        load_tricsr(path, verify=True)


def test_tricsr_detects_truncation_and_bad_magic(tmp_path):
    csr = csr_from_edge_array(kronecker_rmat(6, seed=1))
    path = tmp_path / "g.tricsr"
    save_tricsr(path, csr)
    path.write_bytes(path.read_bytes()[:-8])
    with pytest.raises(CacheError, match="size"):
        load_tricsr(path)
    path.write_bytes(b"NOTTRICS" + b"\0" * 64)
    with pytest.raises(CacheError, match="magic"):
        load_tricsr(path)


def test_tricsr_empty_graph(tmp_path):
    csr = csr_from_edge_array(np.empty((0, 2), np.int32))
    path = tmp_path / "empty.tricsr"
    save_tricsr(path, csr)
    back = load_tricsr(path, verify=True)
    assert back.n_nodes == 0 and back.n_edges == 0
    assert back.edge_array().shape == (0, 2)
    assert TriangleCounter().count(back) == 0


# ---------------------------------------------------------------------------
# sharded slab views (.tricsr.stripe{k}of{N})
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_stripes", [1, 3, 8, 64])
def test_tricsr_stripes_roundtrip(tmp_path, n_stripes):
    """Concat of slab views == the full CSR, bit-for-bit, for stripe
    counts from trivial to more-stripes-than-busy-nodes."""
    from repro.graphs.io import (
        assemble_stripes,
        load_tricsr_stripes,
        save_tricsr_stripes,
    )

    csr = csr_from_edge_array(kronecker_rmat(7, seed=4))
    base = tmp_path / "g.tricsr"
    paths = save_tricsr_stripes(base, csr, n_stripes)
    assert len(paths) == n_stripes
    for mmap in (True, False):
        slabs = load_tricsr_stripes(base, n_stripes, mmap=mmap, verify=True)
        assert [s.stripe_index for s in slabs] == list(range(n_stripes))
        back = assemble_stripes(slabs)
        assert back.n_nodes == csr.n_nodes
        np.testing.assert_array_equal(back.row_offsets, csr.row_offsets)
        np.testing.assert_array_equal(back.col, csr.col)
    # the slab col payloads partition the full col exactly
    slabs = load_tricsr_stripes(base, n_stripes)
    assert sum(s.n_cols for s in slabs) == csr.col.shape[0]


def test_tricsr_stripes_balanced_by_col_count():
    """plan_csr_stripes balances neighbor counts, not node counts: one hub
    node must not drag half the graph into a single slab's tail."""
    from repro.graphs.io import plan_csr_stripes

    # star: node 0 has 1000 neighbors, everyone else 1
    row = np.concatenate([[0], np.arange(1000, 2001)]).astype(np.int64)
    bounds = plan_csr_stripes(row, 4)
    assert bounds[0] == (0, 1)  # the hub is a stripe of its own
    sizes = [int(row[hi]) - int(row[lo]) for lo, hi in bounds]
    assert sum(sizes) == 2000
    assert max(sizes) <= 1000  # no stripe exceeds the hub's load


def test_tricsr_stripe_detects_corruption_per_slab(tmp_path):
    from repro.graphs.io import (
        load_tricsr_stripe,
        save_tricsr_stripes,
        stripe_path,
    )

    csr = csr_from_edge_array(kronecker_rmat(6, seed=1))
    base = tmp_path / "g.tricsr"
    save_tricsr_stripes(base, csr, 4)
    bad = stripe_path(base, 2, 4)
    blob = bytearray(open(bad, "rb").read())
    blob[-3] ^= 0xFF
    open(bad, "wb").write(bytes(blob))
    with pytest.raises(CacheError, match="checksum"):
        load_tricsr_stripe(bad, verify=True)
    # the sibling slabs still verify clean
    for k in (0, 1, 3):
        load_tricsr_stripe(stripe_path(base, k, 4), verify=True)


def test_tricsr_stripe_detects_truncation_magic_and_mismatch(tmp_path):
    from repro.graphs.io import (
        assemble_stripes,
        load_tricsr_stripe,
        load_tricsr_stripes,
        save_tricsr_stripes,
        stripe_path,
    )

    csr = csr_from_edge_array(kronecker_rmat(6, seed=1))
    base = tmp_path / "g.tricsr"
    save_tricsr_stripes(base, csr, 3)
    p = stripe_path(base, 1, 3)
    raw = open(p, "rb").read()
    open(p, "wb").write(raw[:-8])
    with pytest.raises(CacheError, match="size"):
        load_tricsr_stripe(p)
    open(p, "wb").write(b"NOTSLABS" + b"\0" * 64)
    with pytest.raises(CacheError, match="magic"):
        load_tricsr_stripe(p)
    open(p, "wb").write(raw)  # restore
    # a slab set missing a member does not silently assemble
    slabs = load_tricsr_stripes(base, 3)
    with pytest.raises(CacheError, match="3-stripe"):
        assemble_stripes(slabs[:2])


def test_tricsr_stripes_empty_graph(tmp_path):
    from repro.graphs.io import (
        assemble_stripes,
        load_tricsr_stripes,
        save_tricsr_stripes,
    )

    csr = csr_from_edge_array(np.empty((0, 2), np.int32))
    base = tmp_path / "empty.tricsr"
    save_tricsr_stripes(base, csr, 4)
    slabs = load_tricsr_stripes(base, 4, verify=True)
    assert all(s.n_local_nodes == 0 and s.n_cols == 0 for s in slabs)
    back = assemble_stripes(slabs)
    assert back.n_nodes == 0 and back.n_edges == 0


def test_slab_orientation_matches_unsharded(tmp_path, small_graphs):
    """oriented_csr_from_slabs over loaded slab views == prepare_oriented of
    the assembled CSR — the §III-E hand-off from sharded ingest to the
    replicated oriented CSR."""
    from repro.core.distributed import oriented_csr_from_slabs
    from repro.core.engine import prepare_oriented
    from repro.graphs.io import load_tricsr_stripes, save_tricsr_stripes

    csr = csr_from_edge_array(small_graphs["kron"])
    base = tmp_path / "g.tricsr"
    save_tricsr_stripes(base, csr, 5)
    slabs = load_tricsr_stripes(base, 5, verify=True)
    oc = oriented_csr_from_slabs(slabs)
    ref = prepare_oriented(csr, None)
    np.testing.assert_array_equal(np.asarray(oc.src), np.asarray(ref.src))
    np.testing.assert_array_equal(np.asarray(oc.col), np.asarray(ref.col))
    np.testing.assert_array_equal(
        np.asarray(oc.row_offsets), np.asarray(ref.row_offsets)
    )


# ---------------------------------------------------------------------------
# ingest + engine plumbing
# ---------------------------------------------------------------------------


def _write_one_direction(path, edges):
    one = edges[edges[:, 0] < edges[:, 1]]
    np.savetxt(path, one, fmt="%d", delimiter="\t")


def test_ingest_cache_miss_then_hit(tmp_path):
    e = kronecker_rmat(7, seed=9)
    src = tmp_path / "g.txt"
    _write_one_direction(src, e)
    cdir = tmp_path / "cache"
    csr1, s1 = ingest(src, cache_dir=cdir, max_chunk_edges=64)
    assert not s1.cache_hit and s1.raw_edges > 0 and s1.spill_runs >= 1
    csr2, s2 = ingest(src, cache_dir=cdir, max_chunk_edges=64)
    assert s2.cache_hit and s2.raw_edges == 0 and s2.load_s >= 0
    np.testing.assert_array_equal(csr1.edge_array(), csr2.edge_array())
    # touching the source invalidates the cache key
    os.utime(src, ns=(1, 1))
    _, s3 = ingest(src, cache_dir=cdir, max_chunk_edges=64)
    assert not s3.cache_hit


def test_cache_key_includes_storage_and_order(tmp_path):
    # regression: a flat .tricsr and a relabeled .tricsrz of the same
    # source must never collide on one cache path — a stale hit would
    # hand back the wrong storage form (or worse, the wrong node ids)
    from repro.graphs.io import cache_path_for

    e = kronecker_rmat(7, seed=4)
    src = tmp_path / "g.txt"
    _write_one_direction(src, e)
    cdir = tmp_path / "cache"
    os.makedirs(cdir)
    keys = {
        cache_path_for(src, cdir),
        cache_path_for(src, cdir, storage="compressed", order="natural"),
        cache_path_for(src, cdir, storage="compressed", order="degree"),
        cache_path_for(src, cdir, storage="compressed", order="bfs"),
    }
    assert len(keys) == 4  # all four artifacts get distinct paths

    # ingesting flat first must not satisfy a later compressed request
    flat, s1 = ingest(src, cache_dir=cdir)
    assert not s1.cache_hit
    z, s2 = ingest(src, cache_dir=cdir, storage="compressed", order="degree")
    assert not s2.cache_hit  # different artifact: clean miss, not a stale hit
    assert s2.cache_path != s1.cache_path
    assert s2.cache_path.endswith(".tricsrz")
    z2, s3 = ingest(src, cache_dir=cdir, storage="compressed", order="degree")
    assert s3.cache_hit and s3.cache_bytes == os.path.getsize(s3.cache_path)
    # the two forms answer identically (per-node through the perm)
    tc = TriangleCounter(method="wedge_bsearch")
    assert tc.count(z2) == tc.count(flat)
    np.testing.assert_array_equal(z2.map_per_node(tc.per_node(z2)),
                                  tc.per_node(flat))
    # flat storage cannot record a permutation: non-natural order rejects
    with pytest.raises(ValueError):
        ingest(src, cache_dir=cdir, storage="flat", order="degree")
    with pytest.raises(ValueError):
        ingest(src, storage="compressed")  # compressed requires a cache_dir


def test_engine_accepts_cached_csr_and_oriented_csr(tmp_path, small_graphs):
    for name, e in small_graphs.items():
        csr = csr_from_edge_array(e)
        tc = TriangleCounter(method="wedge_bsearch")
        want = tc.count(e)
        assert tc.count(csr) == want, name
        oc = preprocess_host_offload(csr)
        assert tc.count(oc) == want, name
        np.testing.assert_array_equal(tc.per_node(csr), tc.per_node(e))
        np.testing.assert_array_equal(tc.clustering(csr), tc.clustering(e))
        assert tc.transitivity(csr) == pytest.approx(tc.transitivity(e))


def test_csr_from_forward_pairs_matches_lexsort_build(small_graphs):
    from repro.graphs import csr_from_forward_pairs

    for name, e in small_graphs.items():
        canon = canonicalize_edges(e)  # normalize layout: fwd block + mirror
        n = int(canon.max()) + 1 if canon.size else 0
        m = canon.shape[0] // 2
        row_ref, col_ref = edge_array_to_csr(canon, n)
        row, col = csr_from_forward_pairs(canon[:m, 0], canon[:m, 1], n)
        np.testing.assert_array_equal(row, row_ref, err_msg=name)
        np.testing.assert_array_equal(col, col_ref, err_msg=name)
    # interleaved layout (not fwd-block-first) must route to the lexsort
    # path inside csr_from_edge_array and still be correct
    tri = small_graphs["triangle"]
    g = csr_from_edge_array(tri)
    row_ref, col_ref = edge_array_to_csr(tri, 3)
    np.testing.assert_array_equal(g.row_offsets, row_ref)
    np.testing.assert_array_equal(g.col, col_ref)


def test_oriented_from_csr_matches_preprocess(small_graphs):
    import jax.numpy as jnp

    for name, e in small_graphs.items():
        n = int(e.max()) + 1
        row, col = edge_array_to_csr(e, n)
        fast = oriented_from_undirected_csr(row, col, n)
        ref = preprocess(jnp.asarray(e), n_nodes=n)
        for field, a, b in zip(ref._fields, fast, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{name}.{field}")


# ---------------------------------------------------------------------------
# round-trip property tests (hypothesis / stub)
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    st.lists(st.tuples(st.integers(0, 30), st.integers(0, 30)),
             min_size=0, max_size=120),
    st.sampled_from(["text", "mtx"]),
    st.integers(1, 37),
)
def test_roundtrip_property(pairs, fmt, chunk):
    """file → parse → external canonicalize → .tricsr → load ==
    in-memory canonicalize_edges + edge_array_to_csr.

    (tempfile instead of a tmp_path fixture: the hypothesis stub's
    ``@given`` wrapper cannot mix drawn arguments with pytest fixtures.)
    """
    import tempfile

    raw = np.array(pairs, dtype=np.int64).reshape(-1, 2)
    with tempfile.TemporaryDirectory(prefix="tricsr-prop-") as tmp:
        if fmt == "text":
            src = os.path.join(tmp, "g.txt")
            with open(src, "w") as fh:
                fh.write("# prop\n")
                for u, v in raw:
                    fh.write(f"{u}\t{v}\n")
        else:
            src = os.path.join(tmp, "g.mtx")
            with open(src, "w") as fh:
                fh.write("%%MatrixMarket matrix coordinate pattern general\n")
                fh.write(f"31 31 {len(raw)}\n")
                for u, v in raw:
                    fh.write(f"{u + 1} {v + 1}\n")
        cdir = os.path.join(tmp, "cache")
        csr, stats = ingest(src, cache_dir=cdir, max_chunk_edges=chunk)
        mem_edges = canonicalize_edges(raw)
        n = int(mem_edges.max()) + 1 if mem_edges.size else 0
        row, col = edge_array_to_csr(mem_edges, n)
        assert csr.n_nodes == n
        np.testing.assert_array_equal(np.asarray(csr.row_offsets), row)
        np.testing.assert_array_equal(np.asarray(csr.col), col)
        # cache hit returns the identical CSR
        csr2, s2 = ingest(src, cache_dir=cdir, max_chunk_edges=chunk)
        assert s2.cache_hit
        np.testing.assert_array_equal(np.asarray(csr.col), np.asarray(csr2.col))
        # and the engine agrees with the numpy oracle on the loaded CSR
        if mem_edges.size:
            assert TriangleCounter().count(csr) == count_triangles_numpy(mem_edges)


# ---------------------------------------------------------------------------
# the out-of-core oracle (ISSUE acceptance): Kronecker-14 through ≥4 spills
# ---------------------------------------------------------------------------


def test_out_of_core_oracle_kron14(tmp_path):
    e = kronecker_rmat(14, edge_factor=16, seed=0)
    src = tmp_path / "kron14.txt"
    _write_one_direction(src, e)
    cdir = tmp_path / "cache"
    # raw one-direction file has m/2 ≈ 100k+ rows; 1/8 of that forces ≥ 4
    # spill runs through the external sorter
    budget = (e.shape[0] // 2) // 8
    stats = ExternalSortStats()
    chunks = iter_edge_chunks(src, budget)
    canonical = canonicalize_edges_external(
        chunks, max_chunk_edges=budget, stats_out=stats
    )
    assert stats.spill_runs >= 4, stats
    np.testing.assert_array_equal(canonical, e)  # bit-identical

    csr, s1 = ingest(src, cache_dir=cdir, max_chunk_edges=budget)
    assert not s1.cache_hit and s1.spill_runs >= 4
    tc = TriangleCounter(method="wedge_bsearch")
    t_file = tc.count(csr)
    t_mem = tc.count(e)
    assert t_file == t_mem

    csr2, s2 = ingest(src, cache_dir=cdir, max_chunk_edges=budget)
    assert s2.cache_hit and s2.raw_edges == 0 and s2.spill_runs == 0
    assert tc.count(csr2) == t_mem


# ---------------------------------------------------------------------------
# fixture + registry
# ---------------------------------------------------------------------------


def test_karate_fixture_counts_45(tmp_path):
    csr, stats = ingest(KARATE, cache_dir=tmp_path)
    assert csr.n_nodes == 34 and csr.n_edges == 78
    assert TriangleCounter().count(csr) == 45


def test_registry_karate_offline_roundtrip(tmp_path):
    csr, stats, ds = materialize_dataset("karate", tmp_path)
    assert stats.source_kind == "fallback" and not stats.cache_hit
    assert TriangleCounter().count(csr) == ds.triangles == 45
    csr2, s2, _ = materialize_dataset("karate", tmp_path)
    assert s2.cache_hit
    np.testing.assert_array_equal(csr.edge_array(), csr2.edge_array())


def test_registry_fallback_scale_override(tmp_path):
    csr, stats, ds = materialize_dataset(
        "soc-livejournal", tmp_path, fallback_scale=7
    )
    assert stats.source_kind == "fallback"
    assert 0 < csr.n_nodes <= 1 << 7
    # deterministic: same call, same cache file, now a hit
    _, s2, _ = materialize_dataset("soc-livejournal", tmp_path, fallback_scale=7)
    assert s2.cache_hit


def test_registry_fallback_scale_applies_to_non_kronecker(tmp_path):
    # roadnet-ca's fallback is watts_strogatz; --fallback-scale must
    # shrink it too, not silently generate the full 2**17-node graph
    csr, stats, _ = materialize_dataset("roadnet-ca", tmp_path, fallback_scale=6)
    assert stats.source_kind == "fallback"
    assert 0 < csr.n_nodes <= 1 << 6


def test_host_offload_passes_oriented_csr_through(small_graphs):
    e = small_graphs["kron"]
    oc = preprocess_host_offload(e)
    again = preprocess_host_offload(oc)
    assert again is oc  # must not re-orient an already-oriented CSR


def test_registry_download_beats_stale_fallback(tmp_path, monkeypatch):
    # an offline run writes a synthetic fallback; a later --download run
    # must fetch the real file, not silently keep serving the stand-in
    from repro.graphs.io import registry as reg

    _, s1, _ = materialize_dataset("com-dblp", tmp_path, fallback_scale=None,
                                   allow_download=False)
    assert s1.source_kind == "fallback"

    def fake_download(ds, dest):
        with open(KARATE) as src, open(dest, "w") as out:
            out.write(src.read())

    monkeypatch.setattr(reg, "_download", fake_download)
    # the real source is .txt.gz-named and the fake writes plain text, so
    # swap in a .txt-url variant of the dataset for the download leg
    monkeypatch.setitem(
        reg.DATASETS, "com-dblp",
        reg.Dataset(
            name="com-dblp", description="test",
            url="http://example.com/com-dblp.txt",
            sha256=None, n_nodes=34, n_edges=78, triangles=45,
            fallback=reg._kron(16, 4),
        ),
    )
    csr, s2, _ = materialize_dataset("com-dblp", tmp_path, allow_download=True)
    assert s2.source_kind == "download"
    assert TriangleCounter().count(csr) == 45


def test_registry_download_conflicts_with_fallback_scale(tmp_path):
    with pytest.raises(ValueError, match="mutually exclusive"):
        materialize_dataset("com-dblp", tmp_path, allow_download=True,
                            fallback_scale=8)


def test_registry_download_rejected_for_fallback_only_dataset(tmp_path):
    # kron-logn21 has no parseable upstream; an explicit download request
    # must error, not silently count the synthetic stand-in
    with pytest.raises(ValueError, match="no downloadable source"):
        materialize_dataset("kron-logn21", tmp_path, allow_download=True)


def test_ingest_spills_on_disk_without_cache_dir(tmp_path, monkeypatch):
    # no cache_dir: spill runs must land next to the source (real disk),
    # not in the system temp dir (often RAM-backed tmpfs)
    import sys
    import tempfile

    import repro.graphs.io.ingest  # noqa: F401 — ensure module is loaded
    # the package attribute `ingest` is the function; fetch the module
    ing = sys.modules["repro.graphs.io.ingest"]

    e = kronecker_rmat(7, seed=11)
    src = tmp_path / "g.txt"
    _write_one_direction(src, e)
    seen = []
    orig = tempfile.mkdtemp

    def spy(*a, **kw):
        path = orig(*a, **kw)
        seen.append(kw.get("dir"))
        return path

    monkeypatch.setattr(ing.tempfile, "mkdtemp", spy)
    csr, stats = ingest(src, max_chunk_edges=64)
    assert stats.spill_runs >= 1
    assert seen and str(seen[0]) == str(tmp_path)
    # spill dir cleaned up afterwards; only the source file remains
    assert sorted(os.listdir(tmp_path)) == ["g.txt"]


def test_registry_table1_entries_complete():
    assert {"karate", "soc-livejournal", "com-orkut", "kron-logn21"} <= set(DATASETS)
    for ds in DATASETS.values():
        assert ds.fallback is not None, f"{ds.name} has no offline fallback"
        assert ds.url is not None or ds.fallback is not None
