"""Generators, formats, sampler, batching."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis; use the local stub
    from _hypothesis_stub import given, settings, st

from repro.graphs import (
    barabasi_albert,
    canonicalize_edges,
    csr_to_edge_array,
    edge_array_to_csr,
    erdos_renyi,
    kronecker_rmat,
    random_molecule_batch,
    sample_blocks,
    validate_edge_array,
    watts_strogatz,
)


@pytest.mark.parametrize(
    "make",
    [
        lambda: kronecker_rmat(8, seed=0),
        lambda: barabasi_albert(200, 4, seed=0),
        lambda: watts_strogatz(100, 6, 0.2, seed=0),
        lambda: erdos_renyi(100, 300, seed=0),
    ],
)
def test_generators_produce_canonical_arrays(make):
    e = make()
    validate_edge_array(e)
    assert e.shape[0] > 0


def test_generators_deterministic():
    a = kronecker_rmat(8, seed=5)
    b = kronecker_rmat(8, seed=5)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, kronecker_rmat(8, seed=6))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 15), st.integers(0, 15)), min_size=1, max_size=60))
def test_canonicalize_properties(pairs):
    e = canonicalize_edges(np.array(pairs, dtype=np.int64))
    if e.size:
        validate_edge_array(e)


def test_csr_roundtrip():
    e = erdos_renyi(50, 150, seed=1)
    n = int(e.max()) + 1
    row, col = edge_array_to_csr(e, n)
    back = csr_to_edge_array(row, col)
    key = lambda x: np.sort(x[:, 0].astype(np.int64) << 32 | x[:, 1])
    np.testing.assert_array_equal(key(e), key(back))


def test_ws_ring_lattice_degree():
    e = watts_strogatz(40, 6, 0.0, seed=0)
    deg = np.bincount(e[:, 0], minlength=40)
    assert (deg == 6).all()


def test_sampler_shapes_and_membership():
    import jax
    import jax.numpy as jnp

    e = erdos_renyi(30, 120, seed=2)
    n = int(e.max()) + 1
    row, col = edge_array_to_csr(e, n)
    seeds = jnp.arange(5, dtype=jnp.int32)
    blocks = sample_blocks(
        jax.random.PRNGKey(0), jnp.asarray(row, jnp.int32), jnp.asarray(col, jnp.int32),
        seeds, (4, 3),
    )
    assert [f.shape[0] for f in blocks.frontiers] == [5, 20, 60]
    # every sampled neighbor really is a neighbor (or a self-loop fallback)
    row_n, col_n = np.asarray(row), np.asarray(col)
    parents = np.asarray(blocks.frontiers[0])
    children = np.asarray(blocks.frontiers[1]).reshape(5, 4)
    for i, p in enumerate(parents):
        nbrs = set(col_n[row_n[p]:row_n[p + 1]]) | {p}
        assert set(children[i]) <= nbrs


def test_molecule_batch_masks():
    gb = random_molecule_batch(3, 8, 12, 5, seed=0)
    assert gb.node_feat.shape == (3, 8, 5)
    assert gb.edge_src.shape == (3, 12)
    assert ((gb.edge_src >= 0) == gb.edge_mask).all()
