"""Shared fixtures. NOTE: never set --xla_force_host_platform_device_count
here — smoke tests and benches must see the real single-CPU world; only
``repro.launch.dryrun`` (and subprocess helpers below) fake a topology.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 480) -> str:
    """Run a python snippet in a subprocess with N fake CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    if r.returncode != 0:
        raise AssertionError(f"subprocess failed:\nSTDOUT:{r.stdout}\nSTDERR:{r.stderr}")
    return r.stdout


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def small_graphs():
    """A family of small graphs with known-by-bruteforce triangle counts."""
    from repro.graphs import erdos_renyi, kronecker_rmat, watts_strogatz

    return {
        "er": erdos_renyi(40, 120, seed=1),
        "kron": kronecker_rmat(8, edge_factor=8, seed=2),
        "ws": watts_strogatz(60, 6, 0.2, seed=3),
        "triangle": np.array([[0, 1], [1, 0], [1, 2], [2, 1], [0, 2], [2, 0]], np.int32),
    }
