"""trilint fixture: deliberate obs-discipline violation (D1).

Parsed, never imported.  The first span wraps a kernel launch but closes
without a sync point — under JAX's async dispatch the span measures
enqueue latency, not device time.  The second span syncs and is
compliant; the third wraps pure-host work and needs no sync.
"""


def chunk_count_kernel(src, dst):  # stand-in kernel (naming convention)
    return src + dst


def save_stuff(path, data):  # host work: returns only when done
    return len(data)


def unsynced(obs, adj, chunk):
    # D1: kernel launch inside the span, no sync before it closes.
    with obs.span("count.chunk", cat="engine"):
        part = chunk_count_kernel(chunk, adj)
    return part


def synced(obs, adj, chunk):
    # compliant: the launch result is materialized before the span exits.
    with obs.span("count.chunk", cat="engine") as sp:
        part = sp.sync(chunk_count_kernel(chunk, adj))
    return part


def host_only(obs, data):
    # compliant: host work is synchronous; no sync point required.
    with obs.span("ingest.cache_write", cat="io"):
        save_stuff("/tmp/x", data)
