"""trilint fixture: deliberate collective-hygiene violations (C1/C2/C3).

Parsed, never imported.
"""

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh

import numpy as np

MESH = Mesh(np.array(jax.devices()), axis_names=("stripe",))


def merge_partials(x):
    # C1: axis "shard" is not declared by any Mesh/PartitionSpec here.
    return jax.lax.psum(x, "shard")


def rank_offset(x):
    # C2: axis_index in a core/ module — striped outputs must be
    # replicated, not rank-dependent.
    return x + jax.lax.axis_index("stripe")


def launch(fn):
    # C3: shard_map without explicit in_specs/out_specs.
    return shard_map(fn, mesh=MESH)
