"""trilint fixture: deliberate recompile hazard (R1).

A shape-derived value reaches a jit entry point with no pow2 bucket
helper in the enclosing function — every distinct edge count mints a new
trace.  Parsed, never imported.
"""

import jax.numpy as jnp

from repro.core.engine import chunk_count_kernel


def count_exact_shape(src, dst, row, col, deg):
    # R1: wedge_budget tracks the raw data size; the trace cache grows
    # without bound as the graph churns.
    budget = src.shape[0] * 4
    return chunk_count_kernel(
        jnp.asarray(src), jnp.asarray(dst), row, col, deg,
        wedge_budget=budget, n_steps=8,
    )
