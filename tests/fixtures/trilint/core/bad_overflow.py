"""trilint fixture: deliberate overflow-discipline violations (O1/O2/O3).

Never imported — parsed from disk by tests/test_check.py to prove the
`overflow` pass fires.  Lives under a fake `core/` directory so the
counting-path prefix rules apply.
"""

import jax.numpy as jnp
import numpy as np


def count_chunk_total(partials):
    # O1: jnp.sum without dtype= on a counting path (int32 stays int32).
    return jnp.sum(partials)


def host_fold_total(per_node):
    # O2: host fold through int() with no widening before the reduction.
    return int(per_node.sum())


def bucket_indices(mask):
    # O3: index-scale narrowing (nonzero output) with no bound guard.
    idx = np.nonzero(mask)[0]
    return idx.astype(np.int32)
