"""trilint fixture: deliberate decode-narrowing violation (Z1).

Never imported — parsed from disk by tests/test_check.py to prove the
`codec` pass fires.  A compliant twin below shows the guarded form the
pass must NOT flag.
"""

import numpy as np

from repro.distributed.compression import ensure_fits_int32
from repro.graphs.io.codec import decode_varints


def unguarded_block_cols(payload, count):
    # Z1: decoded varint data narrowed to the kernel dtype with no bound
    # check — a payload value >= 2^31 wraps to a negative column id.
    vals = decode_varints(payload, count)
    return vals.astype(np.int32)


def unguarded_scalar_cast(payload):
    # Z1 (scalar form): np.int32() cast of a decoded value.
    first = decode_varints(payload, 1)[0]
    return np.int32(first)


def guarded_block_cols(payload, count):
    # Compliant: bound-checked before narrowing — must not be flagged.
    vals = decode_varints(payload, count)
    ensure_fits_int32(int(vals.max(initial=0)), "decoded column id")
    return vals.astype(np.int32)
