"""trilint fixture: deliberate stats-lifecycle violation (S1).

Parsed, never imported.  `query` reaches the `last_stats` writer through a
private helper but never clears it on entry — the PR 6 stale
`fallback_reason` bug class.
"""


class LeakyEngine:
    def __init__(self):
        self.last_stats = None

    def _record(self, stats):
        self.last_stats = stats

    def _run(self, work):
        self._record({"work": work})
        return 0

    def query(self, work):
        # S1: no `self.last_stats = None` before the private writer chain.
        return self._run(work)

    def count(self, work):
        # compliant entry point: clears before running.
        self.last_stats = None
        return self._run(work)
