"""trilint fixture: deliberate backend-protocol violations (B1/B3/B2).

Parsed, never imported.  Self-contained protocol root so the pass's
in-module chain resolution has something to walk.
"""


def register_backend(name, factory):
    pass


class KernelBackend:
    capabilities: frozenset = frozenset()

    def plan(self, work, budget, *, bucket_pow2=False):
        raise NotImplementedError

    def count_chunk(self, adj, chunk):
        raise NotImplementedError

    def per_node_chunk(self, adj, chunk, n_out):
        raise NotImplementedError

    def support_chunk(self, adj, chunk, m_out):
        raise NotImplementedError


class OverpromisingBackend(KernelBackend):
    # B1: declares per_node but never implements per_node_chunk — the
    # PR 5 silent-fallback bug class.
    # B3: implements support_chunk but does not declare "support".
    capabilities = frozenset({"count", "per_node"})

    def plan(self, work, budget, *, bucket_pow2=False):
        return None

    def count_chunk(self, adj, chunk):
        return 0

    def support_chunk(self, adj, chunk, m_out):
        return 0


class UndeclaredBackend:
    # B2: registered with no capabilities table at all (and B4: no plan).
    def count_chunk(self, adj, chunk):
        return 0


register_backend("overpromising", lambda **kw: OverpromisingBackend(**kw))
register_backend("undeclared", UndeclaredBackend)
