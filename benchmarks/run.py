# One function per paper table. Print ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import argparse
import sys
import traceback

from . import (
    ablations,
    analytics,
    engine_chunking,
    fig1_scaling,
    ingest,
    kernel_micro,
    multidevice,
    section5_approx,
    streaming,
    table1_runtime,
    table2_roofline,
)
from .common import emit

SUITES = {
    "table1": table1_runtime.run,      # Table I  — runtimes + speedups
    "table2": table2_roofline.run,     # Table II — kernel profiling/roofline
    "fig1": fig1_scaling.run,          # Fig. 1   — Kronecker scaling
    "ablations": ablations.run,        # §III-D   — optimization ablations
    "multidevice": multidevice.run,    # §III-E   — multi-device + Amdahl
    "section5": section5_approx.run,   # §V       — exact vs DOULION
    "kernels": kernel_micro.run,       # Pallas kernel micro-sweeps
    "chunking": engine_chunking.run,   # engine — memory-bounded partitioning
    "streaming": streaming.run,        # incremental updates vs full recount
    "ingest": ingest.run,              # out-of-core parse/canonicalize/cache
    "analytics": analytics.run,        # support / k-truss / clustering
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(SUITES), default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = []
    for name, fn in SUITES.items():
        if args.only and name != args.only:
            continue
        try:
            emit(fn())
        except Exception:
            failed.append(name)
            traceback.print_exc(file=sys.stderr)
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
