# One function per paper table. Print ``name,us_per_call,derived`` CSV and
# write one machine-readable BENCH_<suite>.json artifact per suite run.
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

from repro.obs import env_fingerprint

from . import (
    ablations,
    analytics,
    compression,
    engine_chunking,
    fig1_scaling,
    ingest,
    kernel_micro,
    multidevice,
    section5_approx,
    serving,
    streaming,
    table1_runtime,
    table2_roofline,
)
from .common import emit

SUITES = {
    "table1": table1_runtime.run,      # Table I  — runtimes + speedups
    "table2": table2_roofline.run,     # Table II — kernel profiling/roofline
    "fig1": fig1_scaling.run,          # Fig. 1   — Kronecker scaling
    "ablations": ablations.run,        # §III-D   — optimization ablations
    "multidevice": multidevice.run,    # §III-E   — multi-device + Amdahl
    "section5": section5_approx.run,   # §V       — exact vs DOULION
    "kernels": kernel_micro.run,       # Pallas kernel micro-sweeps
    "chunking": engine_chunking.run,   # engine — memory-bounded partitioning
    "serving": serving.run,            # multi-tenant service: batching, snapshots
    "streaming": streaming.run,        # incremental updates vs full recount
    "ingest": ingest.run,              # out-of-core parse/canonicalize/cache
    "compression": compression.run,    # .tricsrz ratio / warm load / locality
    "analytics": analytics.run,        # support / k-truss / clustering
}

BENCH_SCHEMA = "repro-bench-v1"


def write_bench_json(out_dir: str, suite: str, rows, wall_s: float,
                     quick: bool) -> str:
    """Persist one suite's rows as a diffable BENCH_<suite>.json artifact."""
    payload = {
        "schema": BENCH_SCHEMA,
        "suite": suite,
        "quick": quick,
        "wall_s": wall_s,
        "rows": [
            {"name": name, "us_per_call": float(us), "derived": str(derived)}
            for name, us, derived in rows
        ],
        "env": env_fingerprint(),
    }
    path = os.path.join(out_dir, f"BENCH_{suite}.json")
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(SUITES), default=None,
                    help="run a single suite (historical spelling of --suite)")
    ap.add_argument("--suite", action="append", choices=sorted(SUITES),
                    default=None, metavar="NAME",
                    help="run this suite (repeatable; default: all)")
    ap.add_argument("--out-dir", default=".", metavar="DIR",
                    help="where BENCH_<suite>.json artifacts land "
                         "(default: current directory)")
    ap.add_argument("--no-artifacts", action="store_true",
                    help="CSV on stdout only, skip the BENCH_*.json files")
    ap.add_argument("--quick", action="store_true",
                    help="shrunken inputs for CI smoke (suites that honor "
                         "benchmarks.common.quick — smaller graphs, fewer "
                         "sweep points)")
    args = ap.parse_args()
    selected = set(args.suite or [])
    if args.only:
        selected.add(args.only)
    if args.quick:
        os.environ["REPRO_BENCH_QUICK"] = "1"
    if not args.no_artifacts:
        os.makedirs(args.out_dir, exist_ok=True)
    print("name,us_per_call,derived")
    failed = []
    for name, fn in SUITES.items():
        if selected and name not in selected:
            continue
        t0 = time.perf_counter()
        try:
            rows = list(fn())
        except Exception:
            failed.append(name)
            traceback.print_exc(file=sys.stderr)
            continue
        wall_s = time.perf_counter() - t0
        emit(rows)
        if not args.no_artifacts:
            path = write_bench_json(args.out_dir, name, rows, wall_s, args.quick)
            print(f"wrote {path} ({len(rows)} rows, {wall_s:.1f}s)",
                  file=sys.stderr)
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
