"""Paper §III-D optimization ablations, re-expressed for the TPU port.

* packed-key sort (§III-D2)  → ``jnp.lexsort`` (one variadic sort) vs two
  chained stable argsorts,
* counting schedule          → wedge+binary-search vs panel equality vs
  Pallas kernel (the §III-D3/D5 thread-shape tradeoffs become schedule
  choices on a vector machine),
* host-offload preprocessing (§III-D6) → device vs host-offload path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import count_triangles, preprocess, preprocess_host_offload
from repro.graphs import kronecker_rmat

from .common import timeit


def _two_pass_sort(su, sv):
    o1 = jnp.argsort(sv, stable=True)
    su2, sv2 = su[o1], sv[o1]
    o2 = jnp.argsort(su2, stable=True)
    return su2[o2], sv2[o2]


def run():
    rows = []
    edges = kronecker_rmat(12, seed=0)
    n = int(edges.max()) + 1
    e = jnp.asarray(edges)

    lex = jax.jit(lambda u, v: jnp.lexsort((v, u)))
    two = jax.jit(_two_pass_sort)
    u, v = e[:, 0], e[:, 1]
    us_lex = timeit(lambda: jax.block_until_ready(lex(u, v)))
    us_two = timeit(lambda: jax.block_until_ready(two(u, v)))
    rows.append(("ablation/sort/lexsort-packed", us_lex, f"speedup={us_two/us_lex:.2f}x"))
    rows.append(("ablation/sort/two-pass", us_two, "-"))

    for method in ("wedge_bsearch", "panel", "pallas"):
        us = timeit(lambda m=method: count_triangles(edges, method=m), warmup=1, iters=3)
        rows.append((f"ablation/method/{method}", us, "-"))

    rows.extend(run_probe_reduction())
    us_dev = timeit(lambda: jax.block_until_ready(preprocess(e, n_nodes=n).col))
    us_host = timeit(lambda: jax.block_until_ready(preprocess_host_offload(edges, n).col))
    rows.append(("ablation/preprocess/device", us_dev, "-"))
    rows.append(("ablation/preprocess/host-offload", us_host,
                 f"overhead={us_host/us_dev:.2f}x;device_footprint=0.5x"))
    return rows


def run_probe_reduction():
    """§Perf evidence: shorter-side enumeration probe-count reduction."""
    import jax.numpy as jnp

    from repro.core import preprocess
    from repro.graphs import barabasi_albert

    rows = []
    for name, edges in [
        ("kronecker-12", kronecker_rmat(12, seed=0)),
        ("kronecker-14", kronecker_rmat(14, seed=0)),
        ("barabasi-albert-10k", barabasi_albert(10_000, 8, seed=0)),
    ]:
        csr = preprocess(jnp.asarray(edges), n_nodes=int(edges.max()) + 1)
        od = np.asarray(csr.out_degree)
        src, dst = np.asarray(csr.src), np.asarray(csr.col)
        base = int(od[src].sum())
        short = int(np.minimum(od[src], od[dst]).sum())
        rows.append(
            (f"ablation/shorter-side/{name}", 0.0,
             f"probes_base={base};probes_short={short};ratio={short/base:.3f}")
        )
    return rows
