"""Benchmark harness utilities. CSV contract: name,us_per_call,derived."""
from __future__ import annotations

import os
import time


def timeit(fn, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def quick() -> bool:
    """True under ``--quick`` (CI smoke sizing — suites shrink inputs).

    Communicated via env var so suite modules stay plain ``run()``
    functions; ``benchmarks.run`` sets it before dispatching.
    """
    return os.environ.get("REPRO_BENCH_QUICK", "") not in ("", "0")


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
