"""Ingestion throughput: parse / canonicalize / cache at bounded memory.

Emits edges/s for each stage of the out-of-core pipeline on a
Kronecker-13 graph written to disk as a SNAP-style text file, across
several ``max_chunk_edges`` budgets (full, 1/8, 1/32 of the raw edge
list), plus the ``.tricsr`` cache write / mmap-load times and a
cache-loaded count as the exactness gate.  Paste results into
EXPERIMENTS.md §Ingestion.
"""
from __future__ import annotations

import os
import tempfile

import numpy as np

from repro.core import TriangleCounter
from repro.graphs import kronecker_rmat
from repro.graphs.io import (
    ExternalSortStats,
    canonicalize_edges_external,
    ingest,
    iter_edge_chunks,
    load_tricsr,
    save_tricsr,
)
from repro.graphs.io.ingest import csr_from_edge_array

from .common import quick, timeit

SCALE = 13
QUICK_SCALE = 10


def run():
    rows = []
    scale = QUICK_SCALE if quick() else SCALE
    edges = kronecker_rmat(scale, edge_factor=16, seed=0)
    one_dir = edges[edges[:, 0] < edges[:, 1]]
    raw_edges = one_dir.shape[0]

    with tempfile.TemporaryDirectory(prefix="bench-ingest-") as tmp:
        src = os.path.join(tmp, f"kron{scale}.txt")
        np.savetxt(src, one_dir, fmt="%d", delimiter="\t")

        # stage 1: parse only (drain the chunk stream), per budget
        budgets = [raw_edges, max(raw_edges // 8, 1), max(raw_edges // 32, 1)]
        for b in budgets:
            us = timeit(lambda: sum(c.shape[0] for c in iter_edge_chunks(src, b)))
            rows.append((f"ingest/parse/chunk={b}", us,
                         f"{raw_edges / (us / 1e6):.0f} edges/s"))

        # stage 2: parse + external canonicalization, per budget
        for b in budgets:
            def full(b=b, stats=None):
                return canonicalize_edges_external(
                    iter_edge_chunks(src, b), max_chunk_edges=b, stats_out=stats
                )

            us = timeit(full)
            stats = ExternalSortStats()
            canonical = full(stats=stats)
            assert np.array_equal(canonical, edges), "external != in-memory"
            rows.append((f"ingest/canonicalize/chunk={b}", us,
                         f"{raw_edges / (us / 1e6):.0f} edges/s | "
                         f"{stats.spill_runs} spill runs"))

        # stage 3: .tricsr write + mmap load
        csr = csr_from_edge_array(edges)
        path = os.path.join(tmp, "g.tricsr")
        us = timeit(lambda: save_tricsr(path, csr))
        rows.append(("ingest/tricsr-write", us,
                     f"{csr.n_edges / (us / 1e6):.0f} edges/s"))
        us = timeit(lambda: load_tricsr(path, mmap=True))
        rows.append(("ingest/tricsr-load-mmap", us,
                     f"{csr.n_edges / (us / 1e6):.0f} edges/s"))

        # stage 4: end-to-end — cold ingest vs warm (cache-hit) ingest,
        # then a count straight off the memory-mapped CSR
        cache = os.path.join(tmp, "cache")
        cold, s_cold = ingest(src, cache_dir=cache)
        rows.append(("ingest/end-to-end-cold",
                     (s_cold.parse_s + s_cold.csr_build_s + s_cold.cache_write_s) * 1e6,
                     f"{raw_edges / max(s_cold.parse_s + s_cold.csr_build_s, 1e-9):.0f} edges/s"))

        def warm():
            csr_w, s = ingest(src, cache_dir=cache)
            assert s.cache_hit
            return csr_w

        us = timeit(warm)
        rows.append(("ingest/end-to-end-warm", us, "cache hit"))

        tc = TriangleCounter(method="wedge_bsearch")
        t_mem = tc.count(edges)
        warm_csr = warm()
        us = timeit(lambda: tc.count(warm_csr))
        assert tc.count(warm_csr) == t_mem, "cached count != in-memory count"
        rows.append(("ingest/count-from-cache", us, f"T={t_mem}"))
    return rows
