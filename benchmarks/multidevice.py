"""Paper §III-E: multi-device counting + Amdahl split.

Runs in a subprocess with 8 fake CPU devices; reports per-phase times and
the preprocessing fraction that bounds multi-device speedup (the paper
measures 0.08–0.76 across graphs).  Beyond the global count, the striped
backend now carries every engine workload, so the table also times
per-node, per-edge support and a full truss decomposition striped vs
single-device — each row identity-asserted against the wedge schedule
before it is reported (a fast wrong kernel scores zero).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_CODE = """
import json, time
import jax, jax.numpy as jnp, numpy as np
mesh = jax.make_mesh((2, 4), ("data", "model"))
from repro.graphs import kronecker_rmat, watts_strogatz
from repro.core import preprocess, count_triangles_distributed, count_triangles

out = {}
for name, edges in [("kronecker-11", kronecker_rmat(11, seed=0)),
                    ("watts-strogatz-20k", watts_strogatz(20000, 10, 0.1, seed=0))]:
    n = int(edges.max()) + 1
    e = jnp.asarray(edges)
    t0 = time.perf_counter(); csr = preprocess(e, n_nodes=n); jax.block_until_ready(csr.col)
    t0 = time.perf_counter(); csr = preprocess(e, n_nodes=n); jax.block_until_ready(csr.col)
    pre = time.perf_counter() - t0
    count_triangles_distributed(edges, mesh)  # warm
    t0 = time.perf_counter(); t8 = count_triangles_distributed(edges, mesh)
    total8 = time.perf_counter() - t0
    count_triangles(edges)  # warm
    t0 = time.perf_counter(); t1 = count_triangles(edges)
    total1 = time.perf_counter() - t0
    assert t8 == t1
    frac = pre / max(total8, 1e-9)
    out[name] = dict(pre_us=pre*1e6, total8_us=total8*1e6, total1_us=total1*1e6,
                     amdahl_frac=frac, triangles=int(t1))

# --- full-workload striped vs single-device (identity-asserted) -----------
from repro.core import TriangleCounter
from repro.analytics.truss import k_truss_decomposition

e10 = kronecker_rmat(10, seed=0)
dist = TriangleCounter(method="distributed", mesh=mesh)
ref = TriangleCounter(method="wedge_bsearch")
workloads = {}
for kind in ("per_node", "support"):
    d_fn = dist.per_node if kind == "per_node" else dist.edge_support
    r_fn = ref.per_node if kind == "per_node" else ref.edge_support
    a = d_fn(e10); b = r_fn(e10)  # warm + identity
    assert np.array_equal(a, b), kind
    assert dist.last_stats.method == "distributed"
    t0 = time.perf_counter(); d_fn(e10); t8 = time.perf_counter() - t0
    t0 = time.perf_counter(); r_fn(e10); t1 = time.perf_counter() - t0
    workloads[kind] = dict(dist_us=t8*1e6, wedge_us=t1*1e6,
                           n_stripes=dist.last_stats.n_stripes)

e9 = kronecker_rmat(9, edge_factor=8, seed=2)
td = k_truss_decomposition(e9, method="distributed", mesh=mesh)  # warm
tw = k_truss_decomposition(e9, method="wedge_bsearch")
assert td.spectrum() == tw.spectrum()
t0 = time.perf_counter()
td = k_truss_decomposition(e9, method="distributed", mesh=mesh)
t8 = time.perf_counter() - t0
t0 = time.perf_counter()
tw = k_truss_decomposition(e9, method="wedge_bsearch")
t1 = time.perf_counter() - t0
workloads["truss"] = dict(dist_us=t8*1e6, wedge_us=t1*1e6,
                          max_k=td.max_k, rounds=td.rounds)
out["workloads"] = workloads
print(json.dumps(out))
"""


def run():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", _CODE], capture_output=True, text=True,
                       env=env, timeout=480)
    rows = []
    if r.returncode != 0:
        rows.append(("multidevice/FAILED", 0.0, r.stderr.strip().splitlines()[-1][:80]))
        return rows
    data = json.loads(r.stdout.strip().splitlines()[-1])
    workloads = data.pop("workloads", {})
    for name, d in data.items():
        max_speedup = 1.0 / max(d["amdahl_frac"], 1e-9)
        rows.append((f"multidevice/{name}/8dev", d["total8_us"],
                     f"T={d['triangles']};amdahl_frac={d['amdahl_frac']:.2f};"
                     f"max_speedup={min(max_speedup, 8):.2f}x"))
        rows.append((f"multidevice/{name}/1dev", d["total1_us"], "-"))
    for kind, d in workloads.items():
        extra = ";".join(
            f"{k}={v}" for k, v in d.items() if k not in ("dist_us", "wedge_us")
        )
        rows.append((f"multidevice/{kind}/striped-8dev", d["dist_us"], extra or "-"))
        rows.append((f"multidevice/{kind}/wedge-1dev", d["wedge_us"], "-"))
    return rows
