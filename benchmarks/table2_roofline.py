"""Paper Table II: counting-kernel profiling.

The paper reports cache hit rate + achieved bandwidth of the CUDA kernel.
The TPU-dry-run analogue: per graph, the wedge workload (probes), the
traffic the count step must move (jaxpr walker), and the achieved probe
rate of the local run — the bandwidth-utilization story of Table II
reconstructed from the roofline side.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import count_triangles_csr, make_wedge_plan, preprocess
from repro.core.count import count_wedges_found
from repro.graphs import barabasi_albert, kronecker_rmat, watts_strogatz
from repro.launch.flops import trace_cost

from .common import timeit

GRAPHS = {
    "kronecker-11": lambda: kronecker_rmat(11, seed=0),
    "kronecker-12": lambda: kronecker_rmat(12, seed=0),
    "barabasi-albert-10k": lambda: barabasi_albert(10_000, 8, seed=0),
    "watts-strogatz-50k": lambda: watts_strogatz(50_000, 20, 0.1, seed=0),
}


def run():
    rows = []
    for name, make in GRAPHS.items():
        edges = make()
        n = int(edges.max()) + 1
        csr = preprocess(jnp.asarray(edges), n_nodes=n)
        plan = make_wedge_plan(csr)
        cost = trace_cost(lambda c: count_wedges_found(c, plan)[0], csr)
        us = timeit(lambda: count_triangles_csr(csr, plan), warmup=1, iters=3)
        probes_per_us = plan.total_wedges / us
        gb = cost["bytes"] / 1e9
        rows.append(
            (
                f"table2/{name}",
                us,
                f"wedges={plan.total_wedges};traffic_gb={gb:.3f};"
                f"probes_per_us={probes_per_us:.1f};search_steps={plan.n_search_steps}",
            )
        )
    return rows
