"""Paper Fig. 1: runtime scaling across Kronecker scales."""
from __future__ import annotations

from repro.core import count_triangles
from repro.graphs import kronecker_rmat

from .common import timeit


def run():
    rows = []
    prev_us = None
    for scale in (8, 9, 10, 11, 12):
        edges = kronecker_rmat(scale, seed=0)
        t = count_triangles(edges)
        us = timeit(lambda: count_triangles(edges), warmup=1, iters=3)
        growth = f"{us/prev_us:.2f}x" if prev_us else "-"
        rows.append((f"fig1/kronecker-{scale}", us, f"T={t};growth={growth}"))
        prev_us = us
    return rows
