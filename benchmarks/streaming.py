"""Streaming updates: incremental delta-count vs from-scratch recount.

The serving question of the incremental subsystem: at what update-batch
size does maintaining the count incrementally stop paying?  For each
batch size ``b``, a counter is bootstrapped on the Kronecker scale-12
graph minus ``b`` undirected edges and one insert+delete cycle of those
``b`` edges is timed (the cycle restores the state, so every iteration
measures a warm update).  The from-scratch row is the unified engine's
``method="auto"`` full recount of the same graph — the cost an update
would pay without the incremental path.  Exactness is asserted at every
batch size before any time is reported.
"""
from __future__ import annotations

import numpy as np

from repro.core import IncrementalTriangleCounter, TriangleCounter
from repro.graphs import kronecker_rmat, undirected_pairs

from .common import timeit

BATCH_SIZES = (16, 64, 256, 1024, 4096)


def run():
    edges = kronecker_rmat(12, seed=0)
    und = undirected_pairs(edges)
    und = und[np.random.default_rng(0).permutation(und.shape[0])]
    full = TriangleCounter(method="auto")
    expect = full.count(edges)
    us_recount = timeit(lambda: full.count(edges), warmup=1, iters=3)
    rows = [(
        "streaming/recount-full",
        us_recount,
        f"T={expect};m={und.shape[0]};method={full.last_stats.method}",
    )]
    crossover = None
    for b in BATCH_SIZES:
        base, batch = und[:-b], und[-b:]
        ctr = IncrementalTriangleCounter(base)

        def cycle():
            ctr.insert(batch)
            ctr.delete(batch)

        us_update = timeit(cycle, warmup=1, iters=3) / 2.0  # one update per half
        # exactness gate: the full graph's count must be reproduced
        delta = ctr.insert(batch)
        assert ctr.count == expect, (b, ctr.count, expect)
        ctr.delete(batch)
        speedup = us_recount / max(us_update, 1e-9)
        if speedup > 1.0:
            crossover = b
        rows.append((
            f"streaming/incremental-b{b}",
            us_update,
            f"delta={delta};speedup={speedup:.1f}x",
        ))
    rows.append((
        "streaming/crossover",
        0.0,
        f"incremental-beats-recount-up-to-b={crossover}",
    ))
    return rows
