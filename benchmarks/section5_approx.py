"""Paper §V comparison: exact GPU counting vs DOULION-style approximation.

Reports runtime and relative error of the sampled estimate at several
keep-probabilities against the exact count — the accuracy/speed tradeoff
the paper cites when arguing for exact counting.
"""
from __future__ import annotations

import numpy as np

from repro.core import count_triangles, count_triangles_doulion
from repro.graphs import kronecker_rmat

from .common import timeit


def run():
    edges = kronecker_rmat(12, seed=0)
    exact = count_triangles(edges)
    rows = []
    us_exact = timeit(lambda: count_triangles(edges), warmup=1, iters=3)
    rows.append(("section5/exact", us_exact, f"T={exact};err=0%"))
    for p in (0.5, 0.25, 0.1):
        est = np.mean([count_triangles_doulion(edges, p=p, seed=s) for s in range(3)])
        us = timeit(lambda: count_triangles_doulion(edges, p=p, seed=0), warmup=1, iters=3)
        err = abs(est - exact) / exact * 100
        rows.append((f"section5/doulion-p{p}", us, f"T_est={est:.0f};err={err:.1f}%"))
    return rows
