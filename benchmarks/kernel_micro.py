"""Microbenchmarks of the two Pallas kernels (interpret mode on CPU —
relative numbers across tile shapes; absolute TPU numbers come from the
§Roofline analysis)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import count_triangles
from repro.graphs import kronecker_rmat
from repro.kernels.triangle_count.ref import intersect_count_ref
from repro.models.attention import flash_attention_jnp

from .common import timeit


def run():
    rows = []
    rng = np.random.default_rng(0)

    def panels(b, l):
        vals = np.sort(rng.integers(0, 1 << 20, size=(b, l)), axis=1).astype(np.int32)
        return jnp.asarray(vals)

    for b, lu, lv in [(1024, 64, 64), (256, 256, 256), (64, 1024, 1024)]:
        a, c = panels(b, lu), panels(b, lv)
        f = jax.jit(intersect_count_ref)
        us = timeit(lambda: jax.block_until_ready(f(a, c)), warmup=1, iters=3)
        pairs = b * lu * lv
        rows.append((f"kernel/intersect-ref/b{b}xl{lu}x{lv}", us,
                     f"pairs_per_us={pairs/us:.0f}"))

    q = jnp.asarray(rng.normal(size=(1, 4, 512, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 4, 512, 64)), jnp.float32)
    for bk in (128, 256, 512):
        us = timeit(
            lambda bk=bk: jax.block_until_ready(
                flash_attention_jnp(q, k, k, block_k=bk)
            ),
            warmup=1, iters=3,
        )
        rows.append((f"kernel/flash-jnp/block_k{bk}", us, "-"))
    return rows
