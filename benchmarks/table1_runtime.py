"""Paper Table I: counting runtime + speedup over the CPU baseline.

Reduced-scale reproduction (container is a single CPU core — the paper's
GPU/CPU roles are played by the vectorized JAX engine vs the NumPy
baseline; absolute numbers differ, the *structure* of the table is the
reproduction target: per-graph runtime, triangle counts, speedups).

All device-side rows route through :class:`repro.core.TriangleCounter`;
the ``auto`` row exercises the schedule dispatcher, and the ``chunked``
row runs the same engine under a memory budget that forces multiple
launches (the paper's larger-than-memory regime, §III-E).
"""
from __future__ import annotations

import numpy as np

from repro.core import TriangleCounter, count_triangles_numpy
from repro.graphs import barabasi_albert, kronecker_rmat, watts_strogatz

from .common import timeit

GRAPHS = {
    "kronecker-10": lambda: kronecker_rmat(10, seed=0),
    "kronecker-12": lambda: kronecker_rmat(12, seed=0),
    "kronecker-13": lambda: kronecker_rmat(13, seed=0),
    "barabasi-albert-20k": lambda: barabasi_albert(20_000, 8, seed=0),
    "watts-strogatz-100k": lambda: watts_strogatz(100_000, 20, 0.1, seed=0),
}


def run():
    rows = []
    for name, make in GRAPHS.items():
        edges = make()
        engine = TriangleCounter(method="auto")
        t = engine.count(edges)
        method = engine.last_stats.method
        total_wedges = engine.last_stats.total_wedges
        us_jax = timeit(lambda: engine.count(edges), warmup=1, iters=3)
        us_np = timeit(lambda: count_triangles_numpy(edges), warmup=1, iters=3)
        chunked = TriangleCounter(
            method="wedge_bsearch", max_wedge_chunk=max(total_wedges // 8, 1)
        )
        assert chunked.count(edges) == t
        us_ck = timeit(lambda: chunked.count(edges), warmup=1, iters=3)
        m = edges.shape[0] // 2
        rows.append((f"table1/{name}/engine-{method}", us_jax,
                     f"m={m};T={t};speedup={us_np/us_jax:.2f}x"))
        rows.append((f"table1/{name}/engine-chunked", us_ck,
                     f"m={m};T={t};chunks={chunked.last_stats.n_chunks}"))
        rows.append((f"table1/{name}/numpy-cpu", us_np, f"m={m};T={t}"))
    return rows
