"""Compressed ``.tricsrz`` storage: ratio, warm load, and kernel locality.

For each registry graph (offline fallbacks, shrunk under ``--quick``) and
each node ordering (natural / degree / bfs):

* on-disk bytes of the flat ``.tricsr`` vs the delta/varint ``.tricsrz``
  and the resulting compression ratio,
* warm (cache-hit) load time of each form,
* kernel wall-clock of a count on the flat natural-order CSR vs the
  relabeled compressed graph at the **same** method and wedge budget —
  the locality-relabeling win (or cost) net of chunk-wise decode,

with the count asserted bit-identical between the two paths and the
per-node result asserted to map back through the inverse permutation.
Paste results into EXPERIMENTS.md §Compression.
"""
from __future__ import annotations

import os
import tempfile

import numpy as np

from repro.core import TriangleCounter
from repro.graphs.io import ORDERINGS, load_tricsr, load_tricsrz, resolve_to_csr

# (dataset, fallback_scale full, fallback_scale quick) — karate is the real
# fixture; the rest resolve to their deterministic offline stand-ins.
GRAPHS = [
    ("karate", None, None),
    ("com-dblp", 14, 10),
    ("roadnet-ca", 14, 10),
]

METHOD = "wedge_bsearch"
WEDGE_BUDGET = 1 << 20


def run():
    rows = []
    from .common import quick, timeit

    with tempfile.TemporaryDirectory(prefix="bench-compression-") as tmp:
        for name, scale_full, scale_quick in GRAPHS:
            scale = scale_quick if quick() else scale_full
            cache = os.path.join(tmp, "cache")
            flat, info = resolve_to_csr(name, cache, allow_download=False,
                                        fallback_scale=scale)
            flat_path = info["ingest"]["cache_path"]
            flat_bytes = os.path.getsize(flat_path)

            tc = TriangleCounter(method=METHOD, max_wedge_chunk=WEDGE_BUDGET)
            t_flat = tc.count(flat)
            pn_flat = tc.per_node(flat)
            us = timeit(lambda: tc.count(flat))
            rows.append((f"compression/{name}/count-flat", us,
                         f"T={t_flat} | {flat_bytes}B on disk"))
            us = timeit(lambda: load_tricsr(flat_path, mmap=True))
            rows.append((f"compression/{name}/load-flat", us, "warm mmap"))

            for order in ORDERINGS:
                z, zinfo = resolve_to_csr(name, cache, allow_download=False,
                                          fallback_scale=scale,
                                          storage="compressed", order=order)
                z_path = zinfo["ingest"]["cache_path"]
                z_bytes = os.path.getsize(z_path)
                ratio = flat_bytes / max(z_bytes, 1)

                # exactness gate: count and mapped per-node bit-identical
                t_z = tc.count(z)
                assert t_z == t_flat, (name, order, t_z, t_flat)
                assert np.array_equal(z.map_per_node(tc.per_node(z)), pn_flat), \
                    (name, order)

                us = timeit(lambda: load_tricsrz(z_path, mmap=True))
                rows.append((f"compression/{name}/load-z-{order}", us,
                             f"ratio={ratio:.2f}x | z={z_bytes}B | count_ok"))
                us = timeit(lambda: tc.count(z))
                rows.append((f"compression/{name}/count-z-{order}", us,
                             f"T={t_z} | vs flat at equal budget | count_ok"))
    return rows
