"""Analytics throughput: per-edge support, k-truss, engine clustering.

Measures the analytics subsystem on the paper's Kronecker family at
several ``max_wedge_chunk`` budgets **and across kernel backends**
(wedge_bsearch / panel / pallas) — the §Analytics table in
EXPERIMENTS.md.  Support and clustering run on Kronecker-12/13 (the
support pass asserts the acceptance identity ``Σ support == 3·T``
bit-exactly at every budget for every backend); the k-truss peel —
O(rounds) full support recomputes, the heaviest repeated-support
workload in the repo — runs on Kronecker-10 per backend so the suite
stays minutes, not hours, on CPU.
"""
from __future__ import annotations

import time

from repro.analytics import edge_support, k_truss_decomposition
from repro.core import TriangleCounter, prepare_oriented
from repro.graphs import kronecker_rmat

from .common import timeit

BUDGET_FRACTIONS = (1.0, 0.25, 0.0625)

METHODS = ("wedge_bsearch", "panel", "pallas")


def run():
    rows = []
    for scale in (12, 13):
        edges = kronecker_rmat(scale, seed=0)
        csr = prepare_oriented(edges)
        tc = TriangleCounter(method="wedge_bsearch")
        expect = tc.count(csr)
        total = tc.last_stats.total_wedges
        for frac in BUDGET_FRACTIONS:
            budget = None if frac == 1.0 else max(int(total * frac), 1)
            for method in METHODS:
                sup = edge_support(csr, max_wedge_chunk=budget, method=method)
                assert int(sup.support.sum()) == 3 * expect, (scale, budget, method)
                assert sup.method == method, (sup.method, method)
                us = timeit(
                    lambda: edge_support(csr, max_wedge_chunk=budget, method=method),
                    warmup=0, iters=3,
                )
                rows.append((
                    f"analytics/support/kron{scale}/{method}/frac-{frac}",
                    us,
                    f"sum=3T={3*expect};chunks={sup.n_chunks};"
                    f"edges={sup.n_edges}",
                ))
            cc_tc = TriangleCounter(method="wedge_bsearch", max_wedge_chunk=budget)
            us = timeit(lambda: cc_tc.clustering(csr), warmup=0, iters=3)
            rows.append((
                f"analytics/clustering/kron{scale}/frac-{frac}",
                us,
                f"chunks={cc_tc.last_stats.n_chunks};T={expect}",
            ))
    # k-truss: the iterative peel multiplies the support cost by the
    # round count, so measure one decomposition per (backend, budget) on
    # kron10 — every backend must produce the identical spectrum
    edges = kronecker_rmat(10, seed=0)
    csr = prepare_oriented(edges)
    probe = TriangleCounter(method="wedge_bsearch")
    probe.count(csr)
    total = probe.last_stats.total_wedges
    base = None
    for method in METHODS:
        for frac in (1.0, 0.0625):
            budget = None if frac == 1.0 else max(int(total * frac), 1)
            t0 = time.perf_counter()  # single timed run; its result doubles
            dec = k_truss_decomposition(csr, max_wedge_chunk=budget, method=method)
            us = (time.perf_counter() - t0) * 1e6  # as the correctness probe
            spec = dec.spectrum()
            if base is None:
                base = spec
            assert spec == base, (method, frac,
                                  "truss must be backend/budget-independent")
            rows.append((
                f"analytics/truss/kron10/{method}/frac-{frac}",
                us,
                f"max_k={dec.max_k};rounds={dec.rounds};"
                f"launches={dec.n_support_launches}",
            ))
    return rows
