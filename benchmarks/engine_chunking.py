"""Memory-bounded partitioning sweep: runtime vs `max_wedge_chunk`.

Quantifies the cost of the engine's larger-than-memory discipline: the
same graph counted with the wedge buffer capped at decreasing fractions
of its full size.  Because every chunk pads to one static budget, the
sweep compiles each kernel once — the runtime delta is pure launch
overhead plus padding waste, which is the number the §Perf table in
EXPERIMENTS.md tracks (the paper's analogue: edge-list passes on the 89M
edge graph that exceeds the C2050's 3 GB, §III-E/Table I).
"""
from __future__ import annotations

from repro.core import TriangleCounter
from repro.graphs import kronecker_rmat

from .common import quick, timeit

FRACTIONS = (1.0, 0.25, 0.0625, 0.015625)
QUICK_FRACTIONS = (1.0, 0.0625)


def run():
    scale, fractions = (10, QUICK_FRACTIONS) if quick() else (12, FRACTIONS)
    edges = kronecker_rmat(scale, seed=0)
    probe = TriangleCounter(method="wedge_bsearch")
    expect = probe.count(edges)
    total = probe.last_stats.total_wedges
    rows = []
    for frac in fractions:
        budget = None if frac == 1.0 else max(int(total * frac), 1)
        engine = TriangleCounter(method="wedge_bsearch", max_wedge_chunk=budget)
        t = engine.count(edges)
        assert t == expect, (t, expect, budget)
        us = timeit(lambda: engine.count(edges), warmup=1, iters=3)
        st = engine.last_stats
        rows.append((
            f"engine/chunking/frac-{frac}",
            us,
            f"chunks={st.n_chunks};budget={st.peak_wedge_buffer};T={t}",
        ))
    # panel schedule under the same budget discipline
    engine = TriangleCounter(method="panel", max_wedge_chunk=max(total // 16, 1))
    t = engine.count(edges)
    assert t == expect
    us = timeit(lambda: engine.count(edges), warmup=1, iters=3)
    rows.append((
        "engine/chunking/panel-frac-0.0625",
        us,
        f"chunks={engine.last_stats.n_chunks};T={t}",
    ))
    return rows
