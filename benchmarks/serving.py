"""Serving: batched vs sequential throughput, per-class latency, snapshots.

The multi-tenant service's headline claim is the batched-inference one:
under concurrent clients, fusing a dispatch window of queries into one
engine pass multiplies throughput by roughly the window size, because
the pass — not the per-request bookkeeping — is the cost.  This suite
measures exactly that, closed-loop, at increasing client counts:

* ``serving/fused-c{N}``    — default admission policies (fusion on),
  N clients; derived carries qps, per-class p50/p99, and the
  fused-queries / engine-passes accounting that proves batching ran.
* ``serving/sequential-c{N}`` — identical offered load with
  ``max_batch=1`` policies (every query its own engine pass); the
  contrast arm.  The fused row's derived includes the speedup.
* ``serving/mixed-stream``  — queries racing a live update stream
  through a session tenant (update + point + node classes together).
* ``serving/snapshot-roundtrip`` — session state save + restore wall
  time through the checkpoint subsystem.

Every load row asserts answer correctness (the service's count equals
the engine oracle) before timing is reported.
"""
from __future__ import annotations

import tempfile

import numpy as np

from repro.core import TriangleCounter
from repro.graphs import STREAM_GENERATORS, kronecker_rmat
from repro.serve import (
    ClassPolicy,
    DEFAULT_POLICIES,
    GraphManager,
    GraphService,
    SnapshotStore,
    StreamSession,
    run_load,
)

from .common import quick, timeit

GRAPH = "com-dblp"  # offline: deterministic Kronecker fallback at --fallback-scale


def _sequential_policies():
    """Fusion disabled: every class dispatches one request per window."""
    return {
        c: ClassPolicy(max_queue=p.max_queue, timeout_s=p.timeout_s, max_batch=1)
        for c, p in DEFAULT_POLICIES.items()
    }


def _fmt_lat(latency: dict) -> str:
    return ";".join(
        f"{cls}_p50={snap['p50_ms']:.3f}ms,{cls}_p99={snap['p99_ms']:.3f}ms"
        for cls, snap in sorted(latency.items())
    )


def _load_row(cache_dir: str, scale: int, clients: int, requests: int,
              expect: int, policies=None) -> dict:
    mgr = GraphManager(cache_dir)
    with GraphService(mgr, policies=policies) as svc:
        svc.attach(GRAPH, GRAPH, fallback_scale=scale)
        got = svc.query(GRAPH, "count", timeout=600.0)
        assert got == expect, (got, expect)
        # warm every kernel the mix can hit before the timed load — the
        # arms must compare dispatch policies, not compile caches
        for kind in ("per_node", "clustering", "transitivity"):
            svc.query(GRAPH, kind, timeout=600.0)
        rep = run_load(svc, GRAPH, clients=clients,
                       requests_per_client=requests, seed=clients)
    assert rep["errors"]["other"] == 0, rep["errors"]
    return rep


def run():
    scale = 7 if quick() else 9
    client_counts = (1, 2, 4) if quick() else (1, 2, 4, 8)
    requests = 8 if quick() else 24

    rows = []
    with tempfile.TemporaryDirectory() as cache_dir:
        # oracle count for the fallback graph (engine, no service)
        mgr = GraphManager(cache_dir)
        mgr.attach(GRAPH, GRAPH, fallback_scale=scale)
        with mgr.lease(GRAPH) as ent:
            expect = TriangleCounter(method="auto").count(ent.csr)
            n_edges = int(np.asarray(ent.csr.col).shape[0]) // 2

        for c in client_counts:
            seq = _load_row(cache_dir, scale, c, requests, expect,
                            policies=_sequential_policies())
            fused = _load_row(cache_dir, scale, c, requests, expect)
            speedup = fused["qps"] / max(seq["qps"], 1e-9)
            rows.append((
                f"serving/sequential-c{c}",
                seq["elapsed_s"] / max(seq["n_ok"], 1) * 1e6,
                f"qps={seq['qps']:.1f};passes={seq['counters']['serve.engine_passes']};"
                f"{_fmt_lat(seq['latency'])}",
            ))
            rows.append((
                f"serving/fused-c{c}",
                fused["elapsed_s"] / max(fused["n_ok"], 1) * 1e6,
                f"qps={fused['qps']:.1f};speedup={speedup:.2f}x;"
                f"fused_queries={fused['counters']['serve.fused_queries']};"
                f"passes={fused['counters']['serve.engine_passes']};"
                f"{_fmt_lat(fused['latency'])}",
            ))

        # mixed update+query traffic through a stream-session tenant
        edges = kronecker_rmat(scale, seed=0)
        n_nodes = int(edges.max()) + 1
        stream = STREAM_GENERATORS["temporal"](edges, batch_size=256, seed=1)
        mgr = GraphManager(cache_dir)
        with GraphService(mgr) as svc:
            svc.open_session("live", n_nodes=n_nodes)
            rep = run_load(
                svc, "live",
                clients=2 if quick() else 4,
                requests_per_client=requests,
                update_stream=stream,
                max_updates=8 if quick() else 32,
                seed=7,
            )
            live_count = svc.query("live", "count", timeout=600.0)
            oracle = TriangleCounter(method="auto").count(
                svc.session("live").counter.current_edges(), n_nodes=n_nodes)
        assert live_count == oracle, (live_count, oracle)
        rows.append((
            "serving/mixed-stream",
            rep["elapsed_s"] / max(rep["n_ok"] + rep["n_updates"], 1) * 1e6,
            f"qps={rep['qps']:.1f};updates={rep['n_updates']};T={live_count};"
            f"{_fmt_lat(rep['latency'])}",
        ))

        # snapshot/restore round-trip on the live session's state
        sess = StreamSession("snap", n_nodes=n_nodes)
        for i, batch in enumerate(
                STREAM_GENERATORS["temporal"](edges, batch_size=512, seed=2)):
            sess.apply(insert=batch.insert, delete=batch.delete)
            if i >= (3 if quick() else 8):
                break
        with tempfile.TemporaryDirectory() as snap_dir:
            store = SnapshotStore(snap_dir, keep=2)

            def roundtrip():
                store.save(sess)
                hit = store.restore_session("snap")
                assert hit is not None
                assert hit[0].counter.count == sess.counter.count

            us = timeit(roundtrip, warmup=1, iters=2)
        rows.append((
            "serving/snapshot-roundtrip",
            us,
            f"edges={sess.counter.n_edges};T={sess.counter.count};"
            f"graph_edges={n_edges}",
        ))
    return rows
