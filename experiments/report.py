"""Render EXPERIMENTS.md tables from dry-run JSONL records.

Usage: python experiments/report.py experiments/dryrun_baseline.jsonl [section]
"""
from __future__ import annotations

import json
import sys
from collections import defaultdict


def load(path):
    recs = []
    with open(path) as f:
        for line in f:
            if line.strip():
                recs.append(json.loads(line))
    return recs


def fmt_si(x):
    if x == 0:
        return "0"
    for unit, scale in [("T", 1e12), ("G", 1e9), ("M", 1e6), ("k", 1e3)]:
        if abs(x) >= scale:
            return f"{x/scale:.2f}{unit}"
    return f"{x:.2g}"


def dominant_collective(rec):
    c = rec.get("collectives", {}).get("bytes_by_kind", {})
    if not c or not any(c.values()):
        return "-"
    k = max(c, key=c.get)
    return f"{k}:{fmt_si(c[k])}B"


def roofline_table(recs, mesh_filter="16x16"):
    rows = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | bottleneck "
        "| MODEL_FLOPS | useful/HLO | roofline frac | dominant collective |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh_filter:
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['bottleneck']}** | {fmt_si(r['model_flops'])} "
            f"| {r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} "
            f"| {dominant_collective(r)} |"
        )
    return "\n".join(rows)


def dryrun_table(recs):
    rows = [
        "| arch | shape | mesh | compile (s) | arg bytes/dev | temp bytes/dev | collectives (#ops) |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        ma = r.get("memory_analysis") or {}
        counts = r.get("collectives", {}).get("count_by_kind", {})
        n_coll = sum(counts.values())
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']} "
            f"| {fmt_si(ma.get('argument_bytes') or 0)} | {fmt_si(ma.get('temp_bytes') or 0)} "
            f"| {n_coll} |"
        )
    return "\n".join(rows)


if __name__ == "__main__":
    recs = load(sys.argv[1])
    section = sys.argv[2] if len(sys.argv) > 2 else "roofline"
    if section == "roofline":
        print(roofline_table(recs))
    elif section == "dryrun":
        print(dryrun_table(recs))
    elif section == "multipod":
        print(roofline_table(recs, mesh_filter="2x16x16"))
